"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run           # everything
    PYTHONPATH=src python -m benchmarks.run --only fig7 table4
    PYTHONPATH=src python -m benchmarks.run --fast    # reduced sizes
    PYTHONPATH=src python -m benchmarks.run --smoke   # tiniest configs —
        CI runs this so every entry point is exercised on each push and
        benchmark code cannot silently rot (numbers are NOT meaningful)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

MODULES = [
    ("table1", "benchmarks.table1_naive_compression", {}),
    ("fig7", "benchmarks.fig7_kv_clustering",
     {"fast": dict(n_layers=8, tokens=1024, channels=512),
      "full": dict(n_layers=16, tokens=2048, channels=768),
      "smoke": dict(n_layers=2, tokens=256, channels=128)}),
    ("table3", "benchmarks.table3_weight_compression", {}),
    ("fig8", "benchmarks.fig8_bitplane_compressibility", {}),
    ("table2", "benchmarks.table2_dynquant_quality",
     {"fast": dict(eval_tokens=16), "smoke": dict(eval_tokens=8)}),
    ("fig9", "benchmarks.fig9_precision_distribution", {}),
    ("fig10", "benchmarks.fig10_dram_energy", {}),
    ("fig11", "benchmarks.fig11_load_latency", {}),
    ("table4", "benchmarks.table4_hardware_cost", {}),
    ("serving", "benchmarks.serving_throughput",
     {"fast": dict(n_requests=8, rate=0.8, max_steps=200),
      "smoke": dict(n_requests=5, rate=0.8, max_steps=100)}),
    ("engine_util", "benchmarks.engine_utilization",
     {"fast": dict(n_requests=6, rate=0.8, max_steps=150),
      "smoke": dict(n_requests=4, rate=0.8, max_steps=80)}),
    ("serving_sharded", "benchmarks.serving_sharded",
     {"fast": dict(n_requests=8, rate=0.8, max_steps=200),
      "smoke": dict(n_requests=5, rate=0.8, max_steps=100)}),
    ("serving_bitplane", "benchmarks.serving_bitplane",
     {"fast": dict(n_requests=8, rate=0.8, max_steps=200),
      "smoke": dict(n_requests=4, rate=0.8, max_steps=80),
      # campaign artifacts, written next to the --json output: the module
      # receives json_path for the FIRST entry; the rest are companions it
      # derives from it (here: the Perfetto trace of the last
      # bitplane/fused run, ISSUE 7)
      "artifact": ["BENCH_serving.json", "BENCH_serving_trace.json"]}),
    ("serving_weight_stream", "benchmarks.serving_weight_stream",
     {"fast": dict(n_requests=8, rate=0.8, max_steps=200),
      "smoke": dict(n_requests=4, rate=0.8, max_steps=80),
      # merges its rows INTO serving_bitplane's BENCH_serving.json (runs
      # after it, read-modify-write) — same artifact, one more key
      "artifact": ["BENCH_serving.json"]}),
    ("serving_prefix", "benchmarks.serving_prefix",
     {"fast": dict(n_requests=12, max_steps=400),
      "smoke": dict(n_requests=8, share_factors=(1, 4), max_steps=300),
      # merges its shared-vs-cold rows INTO BENCH_serving.json under a
      # "prefix" key (runs after serving_weight_stream, read-modify-write)
      "artifact": ["BENCH_serving.json"]}),
    ("load_harness", "benchmarks.load_harness",
     {"fast": dict(n_requests=16, max_steps=600),
      "smoke": dict(n_requests=10, kinds=("poisson", "bursty"),
                    max_steps=400),
      "artifact": ["BENCH_serving.json"]}),
    ("kernel_bw", "benchmarks.kernel_bandwidth", {}),
    ("roofline", "benchmarks.roofline", {}),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiniest configs, few steps (CI entry-point check)")
    ap.add_argument("--json", default=None, help="dump results as JSON")
    args = ap.parse_args(argv)

    results, failures = {}, []
    artifacts: dict = {}
    for name, modpath, opts in MODULES:
        if args.only and name not in args.only:
            continue
        if args.smoke:
            # smallest knobs known for the module; modules without size
            # knobs run as-is (they are already CI-sized)
            kwargs = opts.get("smoke", opts.get("fast", {}))
        elif args.fast:
            kwargs = opts.get("fast", {})
        else:
            kwargs = opts.get("full", {})
        expected: list = []
        if args.json and "artifact" in opts:
            # campaign modules also write standalone artifact files (the
            # CI job uploads them) into the --json output's directory; the
            # module receives json_path for the first name, companions
            # (e.g. the Perfetto trace) are derived from it
            arts = opts["artifact"]
            arts = [arts] if isinstance(arts, str) else list(arts)
            outdir = os.path.dirname(args.json) or "."
            expected = [os.path.join(outdir, a) for a in arts]
            kwargs = dict(kwargs, json_path=expected[0])
        t0 = time.time()
        try:
            mod = __import__(modpath, fromlist=["run"])
            results[name] = mod.run(**kwargs)
            print(f"[bench] {name} done in {time.time() - t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
            print(f"[bench] {name} FAILED: {e}")
        written = [p for p in expected if os.path.exists(p)]
        if written:
            artifacts[name] = written
            for p in written:
                print(f"[bench] {name} artifact: {p}")
    n_ran = len(results)
    if args.json:
        if artifacts:
            results["_artifacts"] = artifacts
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=str)
    print(f"\n[bench] {n_ran} benchmarks ran, {len(failures)} failures")
    for f_ in failures:
        print("  FAIL", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
