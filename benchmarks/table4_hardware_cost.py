"""Paper Table IV: silicon cost of the LZ4/ZSTD engines at 2 GHz × 32 lanes
(analytic model calibrated to the paper's ASAP7 synthesis), plus the
throughput sanity check against the serving path's bandwidth demand."""

from __future__ import annotations

from benchmarks.common import fmt_table
from repro.memsim.hardware import CompressionEngineModel


def run() -> dict:
    rows, out = [], {}
    for eng in ("lz4", "zstd"):
        m = CompressionEngineModel(eng)
        for bb in (16384, 32768, 65536):
            pp = m.paper_total(bb)
            fit = m.single_lane(bb)
            rows.append([
                eng, bb,
                f"{pp['sl_area_mm2']:.5f}", f"{fit['area_mm2']:.5f}",
                f"{pp['sl_power_mw']:.0f}", f"{fit['power_mw']:.0f}",
                f"{pp['tot_area_mm2']:.3f}", f"{pp['agg_thpt_tbs']:.2f}",
            ])
            out[f"{eng}_{bb}"] = {
                "paper_sl_area": pp["sl_area_mm2"], "model_sl_area": fit["area_mm2"],
                "paper_sl_power": pp["sl_power_mw"], "model_sl_power": fit["power_mw"],
                "tot_area": pp["tot_area_mm2"], "agg_tbs": pp["agg_thpt_tbs"],
            }
    print("\n== Table IV: compression-engine silicon cost (2 GHz, 32 lanes) ==")
    print(fmt_table(rows, ["engine", "block bits", "SL area (paper)",
                           "SL area (fit)", "SL mW (paper)", "SL mW (fit)",
                           "32-lane mm2", "agg TB/s"]))
    # Bandwidth adequacy: decode of a 70B bf16 model at 100 tok/s needs
    # ~140 GB/s × compression ratio of decompressed output.
    demand = 140 * 1.34
    ok = CompressionEngineModel("zstd").sustains_bandwidth(demand, 32768)
    print(f"2 TB/s aggregate >= {demand:.0f} GB/s decode demand: {ok}")
    out["bandwidth_adequate"] = ok
    return out


if __name__ == "__main__":
    run()
