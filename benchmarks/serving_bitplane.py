"""Dense vs bit-plane device KV under the same serving load, and the first
wall-clock bandwidth trajectory (ISSUE 5 + ISSUE 6).

Drives identical mixed-length Poisson traffic through the paged backend
three ways at each ladder mix:

* ``device_kv="dense"`` — decode attends a bf16 cache; the ladder's
  bandwidth saving is accounting-only;
* ``device_kv="bitplane"`` + ``decode_kernel="rung"`` — packed uint8
  planes, one partial-plane Pallas launch per rung in the ladder's static
  rung set, partials merged outside the kernel;
* ``device_kv="bitplane"`` + ``decode_kernel="fused"`` — ONE Pallas launch
  walks the per-page plane map inline (ISSUE 6 tentpole).

Reported per (mix, variant):

* tokens/s — the device paths genuinely differ (einsum vs rung loop vs
  fused kernel), so throughput is measured, not assumed (CPU runs the
  kernels in interpret mode; TPU runs compile them);
* device bytes/decode-token — dense always moves the full-precision page,
  whatever the ladder charged; bit-plane moves the ladder's bytes, and
  ``device_bytes_read`` == the controller's plane-scaled kv_read exactly
  (asserted at every mix);
* roofline fraction — achieved device KV bytes/s over the modeled memctl
  peak (``MemCtlConfig``: lanes x per-lane decompressed-side throughput),
  the first point of the wall-clock bandwidth trajectory;
* fused-vs-rung speedup at each mix.

Bitplane device bytes are asserted ``<=`` dense at every mix, and strictly
``<`` on mixed-ladder rows (a full-precision ladder legitimately moves
exactly the dense byte count).

With ``json_path`` (the driver passes it under ``--json``) the campaign
rows are written to ``BENCH_serving.json`` for the CI artifact.

    PYTHONPATH=src python -m benchmarks.run --only serving_bitplane
"""

from __future__ import annotations

import json

import numpy as np

from benchmarks.common import fmt_table, pct


def _mixed_requests(n, seed, vocab):
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    return [
        Request(rid=i,
                prompt=rng.integers(0, vocab, int(rng.integers(8, 120)))
                .astype(np.int32),
                max_new_tokens=int(rng.choice([4, 8, 16, 24])))
        for i in range(n)
    ]


def _run(model, params, cfg, reqs, arrivals, max_steps=None):
    from repro.serving import ContinuousScheduler, Request

    # warm pass: jit caches key on (model, keeps, kernel) and survive the
    # scheduler, so a throwaway trace moves every compile out of the
    # measured window — tok/s below is steady-state, not compile time
    warm = ContinuousScheduler(model, params, cfg)
    warm.submit(Request(rid=10 ** 6, prompt=np.arange(24, dtype=np.int32),
                        max_new_tokens=4))
    warm.run_until_drained(60)

    sched = ContinuousScheduler(model, params, cfg)
    nxt = 0
    while nxt < len(reqs) or sched.has_work():
        if max_steps is not None and sched.step_count >= max_steps:
            break
        while nxt < len(reqs) and arrivals[nxt] <= sched.step_count:
            sched.submit(reqs[nxt])
            nxt += 1
        sched.step()
    return sched.report(), sched


def _span_latency(rep: dict) -> dict:
    """p50/p99 TTFT + per-token latency from the telemetry spans, in both
    clock domains — the per-request numbers the aggregate report can't
    give (ISSUE 7 satellite)."""
    lat = rep.get("latency", {})
    out = {}
    for key in ("ttft_wall_ns", "ttft_engine_ns",
                "tpot_wall_ns", "tpot_engine_ns"):
        q = lat.get(key, {})
        out[key + "_p50"] = q.get("p50", 0.0)
        out[key + "_p99"] = q.get("p99", 0.0)
    return out


def _peak_device_bytes_per_s(engine) -> float:
    """Modeled memctl peak: lanes x decompressed-side bytes/s per lane."""
    return (engine.lanes * engine.lane_bytes_per_cycle
            * engine.clock_ghz * 1e9)


def run(n_requests: int = 16, rate: float = 0.6, seed: int = 0,
        max_steps: int | None = None, json_path: str | None = None):
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.core.quantization import PrecisionLadder
    from repro.models.model import build_model
    from repro.serving import EngineConfig, TelemetryConfig

    cfg_m = get_config("smollm-135m", smoke=True)
    model = build_model(cfg_m)
    params = model.init(jax.random.PRNGKey(0))
    # telemetry on for every measured run: the campaign's TTFT/TPOT
    # quantiles come from request spans, and the last bitplane/fused run's
    # Perfetto trace ships as a CI artifact next to the JSON
    base = EngineConfig(max_batch=4, max_ctx=256, store_layers=2,
                        telemetry=TelemetryConfig())
    peak = _peak_device_bytes_per_s(base.engine)
    mixes = [
        ("full (16)", None),
        ("top4@16/4@12/rest@8", PrecisionLadder([(4, 16), (4, 12), (-1, 8)])),
        ("top2@16/2@8/rest@4", PrecisionLadder([(2, 16), (2, 8), (-1, 4)])),
    ]
    variants = [("dense", "fused"), ("bitplane", "rung"),
                ("bitplane", "fused")]
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(rng.exponential(1.0 / rate, n_requests)))

    out = {}
    rows = []
    last_sched = None
    for mix_name, ladder in mixes:
        for device_kv, kernel in variants:
            cfg = dataclasses.replace(base, ladder=ladder,
                                      device_kv=device_kv,
                                      decode_kernel=kernel)
            rep, sched = _run(model, params, cfg,
                              _mixed_requests(n_requests, seed, cfg_m.vocab),
                              arrivals, max_steps=max_steps)
            if device_kv == "bitplane" and kernel == "fused":
                last_sched = sched
            if device_kv == "bitplane":
                # the acceptance identity, demonstrated at every mix and
                # on BOTH kernel strategies
                assert rep["device_bytes_read"] == rep["kv_read_device_bytes"]
            dec = max(1, rep["decode_tokens"])
            tok_s = rep.get("decode_tok_per_s", 0)
            bpt = rep["device_bytes_read"] / dec
            variant = (device_kv if device_kv == "dense"
                       else f"{device_kv}/{kernel}")
            rows.append([
                mix_name, variant, f"{tok_s:.1f}", f"{bpt:.0f}",
                f"{rep['kv_read_device_bytes'] / dec:.0f}",
                pct(rep.get("kv_device_bandwidth_saving", 0)),
                f"{tok_s * bpt / peak:.2e}",
            ])
            out[f"{mix_name}/{variant}"] = {
                "decode_tok_per_s": tok_s,
                "device_bytes_per_token": bpt,
                "accounted_bytes_per_token": rep["kv_read_device_bytes"] / dec,
                "device_bandwidth_saving":
                    rep.get("kv_device_bandwidth_saving", 0),
                "roofline_fraction": tok_s * bpt / peak,
                **_span_latency(rep),
            }
    print(fmt_table(rows, ["ladder mix", "device path", "tok/s",
                           "device B/tok", "accounted B/tok",
                           "device bw saving", "roofline frac"]))
    for mix_name, ladder in mixes:
        d = out[f"{mix_name}/dense"]["device_bytes_per_token"]
        for kernel in ("rung", "fused"):
            b = out[f"{mix_name}/bitplane/{kernel}"]["device_bytes_per_token"]
            # dense can never be beaten by a full-precision ladder; a MIXED
            # ladder must strictly shrink the device read
            assert b <= d, (mix_name, kernel, b, d)
            if ladder is not None:
                assert b < d, (mix_name, kernel, b, d)
        r = out[f"{mix_name}/bitplane/rung"]
        f = out[f"{mix_name}/bitplane/fused"]
        f["fused_vs_rung_speedup"] = (
            f["decode_tok_per_s"] / r["decode_tok_per_s"]
            if r["decode_tok_per_s"] else 0.0)
    out["peak_device_bytes_per_s"] = peak
    if json_path is not None:
        with open(json_path, "w") as fh:
            json.dump(out, fh, indent=1)
        print(f"[serving_bitplane] wrote {json_path}")
        if last_sched is not None and last_sched.telemetry.enabled:
            from repro.telemetry import write_perfetto_trace

            trace_path = str(json_path).replace(".json", "") + "_trace.json"
            write_perfetto_trace(last_sched.telemetry, trace_path,
                                 clock_ghz=base.engine.clock_ghz)
            print(f"[serving_bitplane] wrote {trace_path} (Perfetto)")
    print("[serving_bitplane] dense device bytes ignore the ladder "
          "(accounting fiction); bitplane device bytes == the controller's "
          "plane-scaled kv_read — and the fused single-kernel walk turns "
          "the ladder's saving into one launch per decode step")
    return out
