"""Dense vs bit-plane device KV under the same serving load (ISSUE 5).

Drives identical mixed-length Poisson traffic through the paged backend
with ``device_kv="dense"`` (decode attends a bf16 cache; the ladder's
bandwidth saving is accounting-only) and ``device_kv="bitplane"`` (packed
uint8 planes; decode runs the Pallas partial-plane rung kernel and reads
exactly the planes the ladder prescribes), at several ladder mixes:

* tokens/s — the device paths differ (einsum vs rung kernel), so the
  throughput cost/benefit of the packed layout is measured, not assumed
  (on CPU the kernel runs in interpret mode; TPU runs compile it);
* device bytes/decode-token — dense always moves the full-precision page,
  whatever the ladder charged; bit-plane moves the ladder's bytes, and
  ``device_bytes_read`` == the controller's plane-scaled kv_read exactly
  (asserted here, demonstrated per mix);
* the aggressive mixes show device bytes tracking the ladder down while
  the dense column does not move — the paper's "bandwidth scales with
  dynamic quantization" claim crossing from accounting to the device path.

    PYTHONPATH=src python -m benchmarks.run --only serving_bitplane
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, pct


def _mixed_requests(n, seed, vocab):
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    return [
        Request(rid=i,
                prompt=rng.integers(0, vocab, int(rng.integers(8, 120)))
                .astype(np.int32),
                max_new_tokens=int(rng.choice([4, 8, 16, 24])))
        for i in range(n)
    ]


def _run(model, params, cfg, reqs, arrivals, max_steps=None):
    from repro.serving import ContinuousScheduler

    sched = ContinuousScheduler(model, params, cfg)
    nxt = 0
    while nxt < len(reqs) or sched.has_work():
        if max_steps is not None and sched.step_count >= max_steps:
            break
        while nxt < len(reqs) and arrivals[nxt] <= sched.step_count:
            sched.submit(reqs[nxt])
            nxt += 1
        sched.step()
    return sched.report()


def run(n_requests: int = 16, rate: float = 0.6, seed: int = 0,
        max_steps: int | None = None):
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.core.quantization import PrecisionLadder
    from repro.models.model import build_model
    from repro.serving import EngineConfig

    cfg_m = get_config("smollm-135m", smoke=True)
    model = build_model(cfg_m)
    params = model.init(jax.random.PRNGKey(0))
    base = EngineConfig(max_batch=4, max_ctx=256, store_layers=2)
    mixes = [
        ("full (16)", None),
        ("top4@16/4@12/rest@8", PrecisionLadder([(4, 16), (4, 12), (-1, 8)])),
        ("top2@16/2@8/rest@4", PrecisionLadder([(2, 16), (2, 8), (-1, 4)])),
    ]
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(rng.exponential(1.0 / rate, n_requests)))

    out = {}
    rows = []
    for mix_name, ladder in mixes:
        for device_kv in ("dense", "bitplane"):
            cfg = dataclasses.replace(base, ladder=ladder,
                                      device_kv=device_kv)
            rep = _run(model, params, cfg,
                       _mixed_requests(n_requests, seed, cfg_m.vocab),
                       arrivals, max_steps=max_steps)
            if device_kv == "bitplane":
                # the acceptance identity, demonstrated at every mix
                assert rep["device_bytes_read"] == rep["kv_read_device_bytes"]
            dec = max(1, rep["decode_tokens"])
            rows.append([
                mix_name, device_kv,
                f"{rep.get('decode_tok_per_s', 0):.1f}",
                f"{rep['device_bytes_read'] / dec:.0f}",
                f"{rep['kv_read_device_bytes'] / dec:.0f}",
                pct(rep.get("kv_device_bandwidth_saving", 0)),
            ])
            out[f"{mix_name}/{device_kv}"] = {
                "decode_tok_per_s": rep.get("decode_tok_per_s", 0),
                "device_bytes_per_token": rep["device_bytes_read"] / dec,
                "accounted_bytes_per_token": rep["kv_read_device_bytes"] / dec,
                "device_bandwidth_saving":
                    rep.get("kv_device_bandwidth_saving", 0),
            }
    print(fmt_table(rows, ["ladder mix", "device_kv", "tok/s",
                           "device B/tok", "accounted B/tok",
                           "device bw saving"]))
    for mix_name, ladder in mixes[1:]:
        d = out[f"{mix_name}/dense"]["device_bytes_per_token"]
        b = out[f"{mix_name}/bitplane"]["device_bytes_per_token"]
        assert b < d, (mix_name, b, d)
    print("[serving_bitplane] dense device bytes ignore the ladder "
          "(accounting fiction); bitplane device bytes == the controller's "
          "plane-scaled kv_read — the ladder's saving is now wall-clock "
          "bytes on the device bus")
    return out
