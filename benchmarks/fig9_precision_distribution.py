"""Paper Fig. 9: precision distribution of model weights under MoDE-style
context-dependent dynamic quantization (router-controlled precision)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, pct
from repro.core.quantization import BF16_LADDER, FP8_LADDER, INT4_LADDER, RouterPolicy

#: router-affinity thresholds per base precision (the paper's configs:
#: BF16-based models sweep BF16/FP12/FP8/FP6/FP4 etc.)
CONFIGS = {
    "bf16-based": RouterPolicy(
        ("bf16", "fp12", "fp8", "fp6", "fp4"), (0.15, 0.35, 0.6, 0.8),
        dict(BF16_LADDER),
    ),
    "fp8-based": RouterPolicy(
        ("fp8", "fp6", "fp4"), (0.4, 0.75), dict(FP8_LADDER)
    ),
    "int4-based": RouterPolicy(("int4", "int2"), (0.6,), dict(INT4_LADDER)),
}

MODELS = ("llama8b-like", "llama70b-like", "mixtral-like", "llama-moe-like")


def run() -> dict:
    rng = np.random.default_rng(0)
    rows, out = [], {}
    for model in MODELS:
        # router affinities per block: heavy-tailed (few hot experts/blocks)
        n_blocks = 256
        scores = rng.pareto(2.5, n_blocks)
        for base, pol in CONFIGS.items():
            dist = pol.distribution(scores)
            mean_bits = pol.mean_bits(scores)
            rows.append([
                model, base,
                " ".join(f"{p}:{pct(f)}" for p, f in dist.items()),
                f"{mean_bits:.1f}",
                pct(1 - mean_bits / max(pol.ladder.values())),
            ])
            out[f"{model}_{base}"] = {"dist": dist, "mean_bits": mean_bits}
    print("\n== Fig. 9: weight precision distribution under dynamic quant ==")
    print(fmt_table(rows, ["model", "base", "distribution", "mean bits",
                           "bandwidth saved"]))
    return out


if __name__ == "__main__":
    run()
