"""Shared-prefix KV pages: shared vs cold serving economics (ISSUE 10).

Drives the paged backend with waves of requests that share a page-aligned
system prompt, at several *share factors* (requests per distinct prefix),
with prefix sharing ON — and replays the share-factor-8 trace with
sharing OFF as the cold baseline.  Reported per row:

* tok/s and TTFT p50/p99 (wall) — shared admissions skip matched prefill
  chunks entirely, so first tokens arrive earlier;
* hit ratio / bytes deduplicated — ``report()["prefix"]``: the store
  holds ONE copy of a shared prefix regardless of how many requests bind
  it (refcounted content-addressed pages);
* prefill engine jobs — serviced ``KV_WRITE`` count: matched pages are
  bound, not re-compressed, so the lane engine is never charged for them.

Two hard claims are asserted, not just printed:

* sharing is a MEMORY policy, not a numerics change — sampled tokens with
  sharing ON are bit-identical to OFF on the same trace;
* at share factor 8, TTFT p50 is strictly lower AND serviced prefill
  compression jobs are strictly fewer than the cold baseline.

With ``json_path`` the rows are MERGED into ``BENCH_serving.json`` under
a ``"prefix"`` key (after ``serving_weight_stream``, read-modify-write).

    PYTHONPATH=src python -m benchmarks.run --only serving_prefix
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import fmt_table


def _wave_requests(n, share_factor, seed, prefix_pages=6):
    """``n`` requests in ``n // share_factor`` prefix groups: each group
    shares one page-aligned system prompt + a unique per-request tail."""
    from repro.serving import Request
    from repro.serving.kv_cache import PAGE_TOKENS

    rng = np.random.default_rng(seed)
    groups = max(1, n // share_factor)
    prefixes = [rng.integers(0, 500, prefix_pages * PAGE_TOKENS)
                .astype(np.int32) for _ in range(groups)]
    reqs = []
    for i in range(n):
        tail = rng.integers(0, 500, int(rng.integers(4, 20))).astype(np.int32)
        reqs.append(Request(
            rid=i,
            prompt=np.concatenate([prefixes[i % groups], tail]),
            max_new_tokens=int(rng.choice([8, 12, 16])),
        ))
    return reqs


def _run(model, params, cfg, reqs, gap=6, max_steps=None):
    """Staggered submission (one request every ``gap`` steps): later
    arrivals find the donor's prefix registered, which a synchronized
    wave would not (registration flushes after the prefill tick)."""
    from repro.serving import ContinuousScheduler, Request

    warm = ContinuousScheduler(model, params, cfg)
    warm.submit(Request(rid=10 ** 6, prompt=np.arange(16, dtype=np.int32),
                        max_new_tokens=4))
    warm.run_until_drained(60)

    sched = ContinuousScheduler(model, params, cfg)
    nxt = 0
    while nxt < len(reqs) or sched.has_work():
        if max_steps is not None and sched.step_count >= max_steps:
            break
        while nxt < len(reqs) and nxt * gap <= sched.step_count:
            sched.submit(reqs[nxt])
            nxt += 1
        sched.step()
    rep = sched.report()
    return rep, [list(r.output) for r in reqs]


def run(n_requests: int = 16, seed: int = 0, share_factors=(1, 4, 8),
        max_steps: int | None = None, json_path: str | None = None):
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.models.model import build_model
    from repro.serving import EngineConfig, TelemetryConfig

    cfg_m = get_config("smollm-135m", smoke=True)
    model = build_model(cfg_m)
    params = model.init(jax.random.PRNGKey(0))
    base = EngineConfig(max_batch=4, max_ctx=256, store_layers=2,
                        prefix_sharing=True,
                        telemetry=TelemetryConfig(lane_timeline=False))

    out, rows = {}, []

    def measure(cfg, reqs, label):
        rep, toks = _run(model, params, cfg, reqs, max_steps=max_steps)
        lat = rep["latency"]["ttft_wall_ns"]
        px = rep["prefix"]
        kv_writes = rep["engine"]["serviced_jobs"].get("KV_WRITE", 0)
        row = {
            "decode_tok_per_s": rep.get("decode_tok_per_s", 0),
            "ttft_p50_ns": lat["p50"], "ttft_p99_ns": lat["p99"],
            "hit_ratio": px.get("hit_ratio", 0.0),
            "requests_matched": px.get("requests_matched", 0),
            "bytes_deduplicated": px.get("bytes_deduplicated", 0),
            "prefill_chunks_skipped": px.get("prefill_chunks_skipped", 0),
            "kv_write_jobs": kv_writes,
        }
        out[label] = row
        rows.append([label, f"{row['decode_tok_per_s']:.1f}",
                     f"{lat['p50']:.2e}", f"{lat['p99']:.2e}",
                     f"{row['hit_ratio']:.2f}",
                     str(row['bytes_deduplicated']),
                     str(kv_writes)])
        return row, toks

    for sf in share_factors:
        reqs = _wave_requests(n_requests, sf, seed)
        measure(base, reqs, f"shared_x{sf}")

    # cold baseline: the share-factor-max trace replayed with sharing OFF —
    # identical prompts, identical arrivals, no prefix index
    sf = max(share_factors)
    cold_cfg = dataclasses.replace(base, prefix_sharing=False)
    cold_reqs = _wave_requests(n_requests, sf, seed)
    cold, cold_toks = measure(cold_cfg, cold_reqs, "cold")
    shared_reqs = _wave_requests(n_requests, sf, seed)
    shared, shared_toks = measure(base, shared_reqs, f"shared_x{sf}_rerun")
    out["shared"] = out.pop(f"shared_x{sf}_rerun")
    rows[-1][0] = "shared(rerun)"

    # claim 1: sharing never changes a single sampled token
    assert shared_toks == cold_toks, \
        "prefix sharing changed sampled tokens vs the cold baseline"
    # claim 2: the economics — strictly earlier first tokens, strictly
    # fewer lane-engine compression jobs (matched pages are bound, never
    # re-compressed)
    assert shared["ttft_p50_ns"] < cold["ttft_p50_ns"], (shared, cold)
    assert shared["kv_write_jobs"] < cold["kv_write_jobs"], (shared, cold)
    assert shared["requests_matched"] > 0, shared

    print(fmt_table(rows, ["trace", "tok/s", "ttft p50", "ttft p99",
                           "hit ratio", "dedup B", "kv_write jobs"]))
    print("[serving_prefix] shared tokens bit-identical to cold; TTFT p50 "
          f"{cold['ttft_p50_ns'] / max(shared['ttft_p50_ns'], 1):.2f}x "
          f"faster, prefill compression jobs "
          f"{cold['kv_write_jobs']} -> {shared['kv_write_jobs']}")

    if json_path is not None:
        merged = {}
        if os.path.exists(json_path):
            with open(json_path) as fh:
                merged = json.load(fh)
        merged["prefix"] = out
        with open(json_path, "w") as fh:
            json.dump(merged, fh, indent=1)
        print(f"[serving_prefix] merged into {json_path}")
    return out


if __name__ == "__main__":
    run()
