"""Trace-driven multi-tenant load harness (ISSUE 10).

Replays deterministic synthetic traces (``repro.serving.traces``) against
the continuous scheduler with prefix sharing ON, telemetry ON and
admission shedding armed, then grades each trace against TTFT/TPOT SLO
quantiles (the ISSUE 7 latency report):

* **traffic** — heterogeneous request classes (chat with shared system
  prompts, long-doc summarization, agentic tool loops) under a choice of
  arrival processes: ``poisson`` (steady), ``diurnal`` (peak/trough),
  ``bursty`` (thundering herds);
* **SLO grading** — attained TTFT/TPOT p50/p99 (modeled engine clock, the
  deterministic domain) against per-quantile targets; each trace row says
  PASS/miss per objective.  Wall-clock quantiles are reported too but
  never graded — CI machines make them noise;
* **shedding** — ``EngineConfig.shed_latency_ns_max`` rejects arrivals at
  submit when the modeled engine backlog already exceeds the bound;
  ``requests_shed`` per trace shows the policy working under burst;
* **prefix economics** — ``report()["prefix"]``: hit ratio, pages
  shared, bytes deduplicated, prefill chunks skipped.

    PYTHONPATH=src python -m benchmarks.run --only load_harness
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import fmt_table

#: default SLO targets on the MODELED engine clock (ns).  The smoke model
#: under smoke traffic sits comfortably inside these; a saturated bursty
#: trace shows up as a p99 miss, which is exactly the point of grading.
DEFAULT_SLO = {
    "ttft_engine_ns": {"p50": 2.0e6, "p99": 2.0e7},
    "tpot_engine_ns": {"p50": 1.0e6, "p99": 1.0e7},
}


def _drive(model, params, cfg, trace, max_steps=None):
    """Arrival-driven replay: submit each item once the scheduler clock
    reaches its arrival step, then drain."""
    from repro.serving import ContinuousScheduler, Request

    warm = ContinuousScheduler(model, params, cfg)
    warm.submit(Request(rid=10 ** 6, prompt=np.arange(16, dtype=np.int32),
                        max_new_tokens=4))
    warm.run_until_drained(60)

    sched = ContinuousScheduler(model, params, cfg)
    nxt = 0
    while nxt < len(trace) or sched.has_work():
        if max_steps is not None and sched.step_count >= max_steps:
            break
        while (nxt < len(trace)
               and trace[nxt].arrival_step <= sched.step_count):
            sched.submit(trace[nxt].request)
            nxt += 1
        sched.step()
    return sched.report()


def _grade(latency, slo):
    """Per-objective attainment: (metric, quantile, attained, target, ok)."""
    rows = []
    for metric, targets in slo.items():
        q = latency[metric]
        for quant, target in targets.items():
            rows.append((metric, quant, q[quant], target,
                         q[quant] <= target))
    return rows


def run(n_requests: int = 24, rate: float = 0.5, seed: int = 0,
        kinds=("poisson", "diurnal", "bursty"),
        max_steps: int | None = None, slo: dict | None = None,
        shed_latency_ns_max: float = 5.0e7,
        json_path: str | None = None):
    import jax

    from repro.configs.base import get_config
    from repro.models.model import build_model
    from repro.serving import EngineConfig, TelemetryConfig, make_trace

    slo = DEFAULT_SLO if slo is None else slo
    cfg_m = get_config("smollm-135m", smoke=True)
    model = build_model(cfg_m)
    params = model.init(jax.random.PRNGKey(0))
    cfg = EngineConfig(
        max_batch=4, max_ctx=256, store_layers=2,
        prefix_sharing=True,
        shed_latency_ns_max=shed_latency_ns_max,
        telemetry=TelemetryConfig(lane_timeline=False),
    )

    out, rows = {}, []
    for kind in kinds:
        trace = make_trace(n_requests, kind=kind, rate=rate, seed=seed,
                           max_ctx=cfg.max_ctx)
        rep = _drive(model, params, cfg, trace, max_steps=max_steps)
        lat, px = rep["latency"], rep["prefix"]
        graded = _grade(lat, slo)
        misses = [f"{m}.{q}" for m, q, _, _, ok in graded if not ok]
        out[kind] = {
            "requests": lat["requests"],
            "requests_shed": rep["requests_shed"],
            "latency": {m: lat[m] for m in
                        ("ttft_wall_ns", "ttft_engine_ns",
                         "tpot_wall_ns", "tpot_engine_ns")},
            "slo": [{"metric": m, "quantile": q, "attained_ns": a,
                     "target_ns": t, "ok": ok}
                    for m, q, a, t, ok in graded],
            "slo_misses": misses,
            "prefix": px,
        }
        rows.append([
            kind, str(lat["requests"]), str(rep["requests_shed"]),
            f"{lat['ttft_engine_ns']['p50']:.2e}",
            f"{lat['ttft_engine_ns']['p99']:.2e}",
            f"{lat['tpot_engine_ns']['p99']:.2e}",
            f"{px['hit_ratio']:.2f}", str(px["requests_matched"]),
            f"{px['bytes_deduplicated']}",
            "PASS" if not misses else ",".join(misses),
        ])

    print(fmt_table(rows, ["arrivals", "served", "shed", "ttft p50",
                           "ttft p99", "tpot p99", "hit ratio", "matched",
                           "dedup B", "SLO"]))
    # the harness's structural claims: every trace produced a latency
    # report and a prefix report; the chat-heavy mix shared at least one
    # prefix somewhere across the traces (wave-2 arrivals match)
    assert all(v["requests"] > 0 for v in out.values()), out
    assert sum(v["prefix"]["requests_matched"] for v in out.values()) > 0, \
        "no trace produced a single prefix hit — sharing is not engaging"
    print("[load_harness] prefix sharing engaged; SLO grading is on the "
          "modeled engine clock (wall quantiles reported, never graded)")

    if json_path is not None:
        merged = {}
        if os.path.exists(json_path):
            with open(json_path) as fh:
                merged = json.load(fh)
        merged["load_harness"] = out
        with open(json_path, "w") as fh:
            json.dump(merged, fh, indent=1)
        print(f"[load_harness] merged into {json_path}")
    return out


if __name__ == "__main__":
    run()
