"""Kernel-level bandwidth proportionality (the device half of the paper's
claim): bytes the bitplane kernels fetch per precision, plus interpret-mode
correctness timing (NOT wall-clock perf — CPU interpret only)."""

from __future__ import annotations

import jax.numpy as jnp
import ml_dtypes
import numpy as np

from benchmarks.common import fmt_table, pct


def run() -> dict:
    rng = np.random.default_rng(0)
    out = {}

    from repro.kernels.bitplane_matmul import ops as mm
    w = jnp.asarray(rng.normal(0, 0.02, (1024, 512)).astype(ml_dtypes.bfloat16))
    x = jnp.asarray(rng.normal(0, 1, (64, 1024)).astype(ml_dtypes.bfloat16))
    planes = mm.pack_weights(w)
    full = 1024 * 512 * 2
    rows = []
    for keep in (16, 12, 8, 6, 4):
        fetch = mm.weight_fetch_bytes(planes, keep)
        y = mm.bitplane_matmul(x, planes, keep=keep)
        ref = jnp.dot(x, w, preferred_element_type=jnp.float32)
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        rows.append([f"bf16->top{keep}", f"{fetch:,}", pct(fetch / full),
                     f"{rel:.4f}"])
        out[f"matmul_keep{keep}"] = {"fetch_frac": fetch / full, "rel_err": rel}
    print("\n== bitplane_matmul: weight HBM bytes vs precision ==")
    print(fmt_table(rows, ["precision", "fetch bytes", "of bf16", "rel err"]))

    from repro.kernels.paged_attention import ops as pa
    B, S, Hkv, rep, hd = 1, 256, 2, 2, 64
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, hd)).astype(ml_dtypes.bfloat16))
    kp = pa.pack_kv_planes(k)
    full_kv = 2 * B * S * Hkv * hd * 2
    rows = []
    for name, ladder in {
        "all bf16": ((0, 256, 16),),
        "top16/mid8/rest4": ((0, 64, 16), (64, 192, 8), (192, 256, 4)),
        "all fp8-ish": ((0, 256, 8),),
    }.items():
        fetch = pa.kv_fetch_bytes(kp, ladder)
        rows.append([name, f"{fetch:,}", pct(fetch / full_kv)])
        out[f"kv_{name}"] = fetch / full_kv
    print("\n== paged_attention: KV HBM bytes vs ladder ==")
    print(fmt_table(rows, ["ladder", "fetch bytes", "of bf16"]))
    return out


if __name__ == "__main__":
    run()
