"""Paper Table II proxy: decode quality under KV dynamic quantization.

The paper reports LLaMA-8B perplexity on BookSum (10.49 full KV -> 11.60
with a top-5-BF16/next-5-FP8 ladder vs 14.33 sliding-window and 12.49
Quest-top-5).  Offline we cannot run LLaMA-8B, so the reproduction uses the
repo's own briefly-trained smoke model and reports *cross-entropy of the
next-token prediction* under exactly the same KV policies, plus the plane-
truncation RMSE ladder (quality proxy).  The claim being checked is the
ORDERING:  full < dyn-quant(mixed) < quest(drop) < sliding-window.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table
from repro.configs.base import get_config
from repro.core.bitplane import BF16
from repro.core.quantization import truncate_values
from repro.data import DataConfig, ShardedLoader
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def _trained_smoke(arch="smollm-135m", steps=220, seed=0):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    dc = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8, seed=seed)
    loader = ShardedLoader(dc)
    ocfg = AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=steps)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, g = jax.value_and_grad(model.loss)(params, batch)
        params, opt, _ = adamw_update(g, opt, params, ocfg)
        return params, opt, loss

    loss = None
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in loader.batch_at(s).items()}
        params, opt, loss = step(params, opt, b)
    return cfg, model, params, float(loss)


def _policy_kv(cache, policy: str, page: int = 16):
    """Apply a KV policy to the (L,B,S,H,hd) prefill cache."""
    k, v = np.asarray(cache["k"], np.float32), np.asarray(cache["v"], np.float32)
    s = k.shape[2]
    n_pages = s // page
    keep_planes = np.full(n_pages, 16)
    drop_page = np.zeros(n_pages, bool)
    recency = np.arange(n_pages)  # later pages = more recent
    order = recency[::-1]  # rank by recency (proxy criticality: recent first)
    if policy == "full":
        pass
    elif policy == "window4":  # sliding window: keep last 4 pages
        drop_page[order[4:]] = True
    elif policy == "quest5":  # top-5 pages bf16, rest dropped
        drop_page[order[5:]] = True
    elif policy == "dyn_5_3_2":  # 5 bf16 / 3 fp8 / 2 fp4 / rest fp4
        keep_planes[order[5:8]] = 8
        keep_planes[order[8:]] = 4
    elif policy == "dyn_5_5":  # 5 bf16 / 5 fp8 / rest fp8
        keep_planes[order[5:]] = 8
    else:
        raise ValueError(policy)

    import ml_dtypes

    def apply(t):
        x = jnp.asarray(t.astype(ml_dtypes.bfloat16))
        out = []
        for p in range(n_pages):
            seg = x[:, :, p * page:(p + 1) * page]
            if drop_page[p]:
                seg = jnp.zeros_like(seg)  # masked out via value zeroing
            elif keep_planes[p] < 16:
                seg = truncate_values(seg, int(keep_planes[p]), BF16)
            out.append(seg)
        return jnp.concatenate(out, axis=2)

    new = dict(cache)
    new["k"], new["v"] = apply(k), apply(v)
    return new


def run(eval_tokens: int = 48) -> dict:
    cfg, model, params, train_loss = _trained_smoke()
    dc = DataConfig(vocab=cfg.vocab, seq_len=160 + eval_tokens, global_batch=8, seed=99)
    batch = ShardedLoader(dc).batch_at(0)
    prompt = jnp.asarray(batch["tokens"][:, :160])
    gold = batch["tokens"][:, 160:160 + eval_tokens]

    _, cache0 = jax.jit(model.prefill)(params, {"tokens": prompt})
    decode = jax.jit(model.decode)

    def ce_under(policy):
        from repro.models.model import prepare_decode_cache

        cache = _policy_kv(cache0, policy)
        cache = prepare_decode_cache(cfg, cache, 160 + eval_tokens)
        nll, count = 0.0, 0
        tok = prompt[:, -1]
        cache = dict(cache)
        for t in range(eval_tokens):
            logits, cache = decode(params, tok, cache)
            logp = jax.nn.log_softmax(logits[:, : cfg.vocab], axis=-1)
            g = jnp.asarray(gold[:, t])
            nll += float(-jnp.take_along_axis(logp, g[:, None], 1).mean())
            count += 1
            tok = g  # teacher forcing
        return nll / count

    policies = ["full", "dyn_5_5", "dyn_5_3_2", "quest5", "window4"]
    results = {p: ce_under(p) for p in policies}
    rows = [[p, f"{results[p]:.3f}"] for p in policies]
    print("\n== Table II proxy: decode CE under KV policies "
          f"(smoke model, train loss {train_loss:.2f}) ==")
    print(fmt_table(rows, ["policy", "decode CE (nats)"]))
    print("paper ordering (perplexity): full 10.49 < dyn(5bf16+5fp8) 11.60 < "
          "dyn(5/3/2) 11.87 < quest-top5 12.49 < window 14.33")
    ok = (results["full"] <= results["dyn_5_5"] + 0.02
          and results["dyn_5_5"] <= results["quest5"] + 0.05
          and results["quest5"] <= results["window4"] + 0.2)
    print(f"ordering reproduced: {ok}")
    results["ordering_ok"] = ok
    return results


if __name__ == "__main__":
    run()
