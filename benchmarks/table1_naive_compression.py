"""Paper Table I: naive LZ4/ZSTD on raw (byte-layout) weights and KV.

Claim reproduced: straightforward compression barely works — LZ4 ≈ 0 % on
everything; ZSTD gets ~17–23 % on BF16 weights and ≤ 6.5 % on KV."""

from __future__ import annotations

from benchmarks.common import fmt_table, pct
from repro.core.bitplane import BF16
from repro.core.compressed_store import StoreConfig, compress_kv, compress_weights
from repro.core.surrogates import gaussian_weights, logmag_kv_cache

MODELS = {
    "llama8b-like": dict(shape=(4096, 4096), sigma=0.018),
    "gemma2b-like": dict(shape=(2048, 2048), sigma=0.03),
    "mistral7b-like": dict(shape=(4096, 4096), sigma=0.015),
}


def run() -> dict:
    rows, out = [], {}
    for name, spec in MODELS.items():
        w = gaussian_weights(spec["shape"], sigma=spec["sigma"], seed=hash(name) % 100)
        kv = logmag_kv_cache(2048, 512, rho=0.995, seed=hash(name) % 50)
        cells = {}
        for codec in ("lz4", "zstd"):
            cfg = StoreConfig(codec=codec, layout="raw")
            cells[f"w_{codec}"] = compress_weights(w, BF16, cfg).savings
            cells[f"kv_{codec}"] = compress_kv(kv, BF16, cfg).savings
        rows.append([
            name, pct(cells["w_lz4"]), pct(cells["w_zstd"]),
            pct(cells["kv_lz4"]), pct(cells["kv_zstd"]),
        ])
        out[name] = cells
    print("\n== Table I: naive (byte-layout) lossless compression ==")
    print(fmt_table(rows, ["model", "W lz4", "W zstd", "KV lz4", "KV zstd"]))
    print("paper: weights lz4 0-18%, zstd 17-23%; KV lz4 0%, zstd 0.9-6.5%")
    return out


if __name__ == "__main__":
    run()
