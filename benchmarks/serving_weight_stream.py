"""Resident vs streamed block-compressed weights under serving load (ISSUE 9).

Drives identical Poisson traffic through the paged backend twice:

* ``weight_stream="resident"`` — layer weights live uncompressed on-device;
  the scheduler never submits a WEIGHT_FETCH job (the baseline every prior
  campaign row was measured against);
* ``weight_stream="compressed"`` — weights live block-compressed behind the
  memory controller and a ``WeightStreamer`` double-buffers layer
  decompresses through the same lane budget KV fetches contend for.

Reported per mode:

* tokens/s — streamed compute is bit-identical (asserted on every request's
  output tokens), so any delta is pure modeling overhead, not numerics;
* weight bytes/decode-token — physical (compressed) weight-read traffic per
  generated token, the number the paper's weight-side 25.2% is quoted over;
* weight bandwidth saving — ``report()["weights"]["bandwidth_saving"]``,
  the ONE savings definition shared with table3 (exact block bytes, never
  padded bytes);
* stall fraction — steps that closed their lane window before the pass's
  layers finished fetching, charged to modeled latency.

With ``json_path`` (the driver passes it under ``--json``) the rows are
MERGED into ``BENCH_serving.json`` under a ``"weight_stream"`` key — the
module runs after ``serving_bitplane`` and must not clobber its campaign
rows.

    PYTHONPATH=src python -m benchmarks.run --only serving_weight_stream
"""

from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import fmt_table, pct


def _mixed_requests(n, seed, vocab):
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    return [
        Request(rid=i,
                prompt=rng.integers(0, vocab, int(rng.integers(8, 96)))
                .astype(np.int32),
                max_new_tokens=int(rng.choice([4, 8, 16])))
        for i in range(n)
    ]


def _run(model, params, cfg, reqs, arrivals, max_steps=None):
    from repro.serving import ContinuousScheduler, Request

    # warm pass: move every jit compile out of the measured window so tok/s
    # compares steady-state decode, not trace time
    warm = ContinuousScheduler(model, params, cfg)
    warm.submit(Request(rid=10 ** 6, prompt=np.arange(16, dtype=np.int32),
                        max_new_tokens=4))
    warm.run_until_drained(60)

    sched = ContinuousScheduler(model, params, cfg)
    nxt = 0
    while nxt < len(reqs) or sched.has_work():
        if max_steps is not None and sched.step_count >= max_steps:
            break
        while nxt < len(reqs) and arrivals[nxt] <= sched.step_count:
            sched.submit(reqs[nxt])
            nxt += 1
        sched.step()
    return sched.report(), [list(r.output) for r in reqs]


def run(n_requests: int = 12, rate: float = 0.6, seed: int = 0,
        max_steps: int | None = None, json_path: str | None = None):
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.models.model import build_model
    from repro.serving import EngineConfig

    cfg_m = get_config("smollm-135m", smoke=True)
    model = build_model(cfg_m)
    params = model.init(jax.random.PRNGKey(0))
    base = EngineConfig(max_batch=4, max_ctx=256, store_layers=2,
                        weight_stream="resident")
    reqs_args = (n_requests, seed, cfg_m.vocab)
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(rng.exponential(1.0 / rate, n_requests)))

    out, rows, tokens = {}, [], {}
    for mode in ("resident", "compressed"):
        cfg = dataclasses.replace(base, weight_stream=mode)
        reqs = _mixed_requests(*reqs_args)
        rep, outs = _run(model, params, cfg, reqs, arrivals,
                         max_steps=max_steps)
        tokens[mode] = outs
        dec = max(1, rep["decode_tokens"])
        tok_s = rep.get("decode_tok_per_s", 0)
        w = rep["weights"]
        if mode == "resident":
            bpt, saving, stall = 0.0, 0.0, 0.0
        else:
            bpt = w["read_physical_bytes"] / dec
            saving = w["bandwidth_saving"]
            stall = w["stall_fraction"]
        rows.append([mode, f"{tok_s:.1f}", f"{bpt:.0f}", pct(saving),
                     f"{stall:.3f}"])
        out[mode] = {
            "decode_tok_per_s": tok_s,
            "weight_bytes_per_token": bpt,
            "weight_bandwidth_saving": saving,
            "stall_fraction": stall,
            "decode_tokens": rep["decode_tokens"],
            "weights": w,
        }

    # the subsystem's whole claim: streaming is a memory-system model, not
    # a numerics change — every request's tokens must match exactly
    assert tokens["compressed"] == tokens["resident"], \
        "streamed decode diverged from resident weights"
    ws = out["compressed"]["weights"]
    assert 0.0 < ws["bandwidth_saving"] < 1.0, ws

    print(fmt_table(rows, ["weight mode", "tok/s", "weight B/tok",
                           "weight bw saving", "stall frac"]))
    print("[serving_weight_stream] streamed tokens bit-identical to "
          "resident; weight bandwidth saving is table3's exact-block "
          "definition (paper ballpark: ~25.2% on bf16 surrogates)")

    if json_path is not None:
        # merge, don't clobber: serving_bitplane owns this file and writes
        # its campaign rows first in the same --json run
        merged = {}
        if os.path.exists(json_path):
            with open(json_path) as fh:
                merged = json.load(fh)
        merged["weight_stream"] = out
        with open(json_path, "w") as fh:
            json.dump(merged, fh, indent=1)
        print(f"[serving_weight_stream] merged into {json_path}")
    return out


if __name__ == "__main__":
    run()
