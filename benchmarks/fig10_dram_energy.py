"""Paper Fig. 10: DRAM access energy per weight — Proposed bit-plane (P) vs
Traditional byte-level (T) layout, under the Fig. 9 dynamic-quant mixes.

P moves ``compressed × (mean_bits/16)`` bytes (partial-plane fetch of the
compressed planes); T moves the raw bytes of whatever lossy base format the
model ships in (dynamic quantization cannot reduce DRAM traffic in a
byte-interleaved layout — the paper's §II.C 'missing link')."""

from __future__ import annotations

from benchmarks.common import fmt_table, pct
from repro.core.controller import AccessEvent
from repro.memsim.trace import replay_controller_trace

#: (model, base precision) -> (total weight GB at base precision,
#: lossless plane-compression factor, mean fetched bits / base bits)
#: — compression factors from table3, precision mixes from fig9.
SCENARIOS = {
    ("llama8b", "bf16"): (16.0, 1.34, None),
    ("llama8b", "fp8"): (8.0, 1.09, None),
    ("llama8b", "int4"): (4.0, 1.01, None),
    ("llama70b", "bf16"): (140.0, 1.34, None),
    ("llama70b", "fp8"): (70.0, 1.10, None),
    ("llama70b", "int4"): (35.0, 1.02, None),
    ("mixtral", "bf16"): (86.0, 1.32, None),
    ("mixtral", "fp8"): (43.0, 1.09, None),
    ("mixtral", "int4"): (21.5, 1.01, None),
    ("llama-moe", "bf16"): (7.0, 1.33, None),
    ("llama-moe", "fp8"): (3.5, 1.11, None),
    ("llama-moe", "int4"): (1.75, 1.02, None),
}

#: mean fetched fraction of the base bits under the paper's Fig. 9 router
#: mixes.  Fig. 9 is plot-only (no table), so these fractions are the ones
#: implied by the paper's own Fig. 10/11 reductions given the Table III
#: compression ratios — i.e. we calibrate the precision mix, then check the
#: latency/energy pipeline reproduces the reductions end-to-end.
FETCH_FRAC = {"bf16": 0.93, "fp8": 0.90, "int4": 0.86}

N_LAYERS = 32
ACTIVE_FRAC = {"llama8b": 1.0, "llama70b": 1.0, "mixtral": 0.28, "llama-moe": 0.35}


def _trace(total_gb, per_read_scale, model):
    per_layer = int(total_gb * 1e9 * ACTIVE_FRAC[model] / N_LAYERS)
    return [
        AccessEvent("weight_read", f"l{i}", per_layer, int(per_layer * per_read_scale))
        for i in range(N_LAYERS)
    ]


def run() -> dict:
    rows, out = [], {}
    for (model, base), (gb, ratio, _) in SCENARIOS.items():
        frac = FETCH_FRAC[base]
        # Traditional: raw base-precision bytes (dyn-quant saves nothing).
        t = replay_controller_trace(_trace(gb, 1.0, model))
        # Proposed: compressed planes × fetched fraction.
        p = replay_controller_trace(_trace(gb, frac / ratio, model))
        e_t, e_p = t.energy["total_uj"], p.energy["total_uj"]
        rows.append([
            model, base, f"{e_t:,.0f}", f"{e_p:,.0f}", pct(1 - e_p / e_t),
        ])
        out[f"{model}_{base}"] = {
            "energy_T_uj": e_t, "energy_P_uj": e_p,
            "reduction": 1 - e_p / e_t,
        }
    print("\n== Fig. 10: DRAM access energy, Proposed (P) vs Traditional (T) ==")
    print(fmt_table(rows, ["model", "base", "T energy (uJ)", "P energy (uJ)",
                           "reduction"]))
    print("paper: bf16-based reductions 25.9-29.9%; fp8 ~17.9-19.6%; "
          "int4 smaller (trend: savings shrink with base precision)")
    return out


if __name__ == "__main__":
    run()
