"""Paper Fig. 8: per-bit-plane compressibility — weights (BF16/FP8/INT4)
and KV cache.  Exponent planes dominate the win; lossy-quantized formats
lose the redundancy."""

from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table
from repro.core.bitplane import BF16, FP8_E4M3, INT4
from repro.core.compressed_store import StoreConfig, compress_kv, compress_weights
from repro.core.surrogates import (
    gaussian_weights,
    logmag_kv_cache,
    quantized_weights_fp8,
    quantized_weights_int4,
)


def _plane_ratios(ct):
    stored = ct.plane_stored_bytes().astype(float)
    logical = ct.plane_logical_bytes().astype(float)
    return logical / np.maximum(stored, 1)


def run() -> dict:
    cfg = StoreConfig(codec="zstd")
    out = {}
    shape = (2048, 4096)
    cases = {
        "weights bf16": (compress_weights(gaussian_weights(shape, seed=1), BF16, cfg), BF16),
        "weights fp8": (compress_weights(quantized_weights_fp8(shape, seed=1), FP8_E4M3, cfg), FP8_E4M3),
        "weights int4": (compress_weights(quantized_weights_int4(shape, seed=1), INT4, cfg), INT4),
        "kv bf16 (wikitext-like)": (
            compress_kv(logmag_kv_cache(2048, 1024, rope_frac=0.5, seed=2), BF16, cfg), BF16),
        "kv bf16 (booksum-like)": (
            compress_kv(logmag_kv_cache(2048, 1024, rho=0.999, rope_frac=0.5, seed=3), BF16, cfg), BF16),
    }
    rows = []
    for name, (ct, spec) in cases.items():
        pr = _plane_ratios(ct)
        head = " ".join(f"{r:4.1f}" for r in pr[: min(8, spec.bits)])
        tail = " ".join(f"{r:4.1f}" for r in pr[min(8, spec.bits):])
        rows.append([name, f"{ct.ratio:.2f}", head, tail])
        out[name] = {"overall": ct.ratio, "per_plane": pr.tolist()}
        if spec is BF16:
            exp_mean = pr[1:9].mean()
            man_mean = pr[9:].mean()
            out[name]["exp_over_mantissa"] = float(exp_mean / man_mean)
    print("\n== Fig. 8: per-plane ZSTD ratios (plane 0 = sign/MSB) ==")
    print(fmt_table(rows, ["tensor", "overall", "planes 0-7", "planes 8+"]))
    print("paper: BF16 top-4 exponent planes dominate (overall 1.34); "
          "FP8/INT4 show little per-plane redundancy; KV exponent planes "
          "compress strongly")
    return out


if __name__ == "__main__":
    run()
