"""Shared benchmark utilities: table formatting, surrogate suites, KV
harvesting from the repo's own models, timing."""

from __future__ import annotations

import time

import numpy as np


def fmt_table(rows: list, headers: list) -> str:
    widths = [
        max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
        for i, h in enumerate(headers)
    ]
    line = " | ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    sep = "-+-".join("-" * w for w in widths)
    body = "\n".join(
        " | ".join(str(c).ljust(w) for c, w in zip(r, widths)) for r in rows
    )
    return f"{line}\n{sep}\n{body}"


def pct(x: float) -> str:
    return f"{100 * x:.1f}%"


def time_call(fn, *args, reps: int = 3, **kw):
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6  # us


def harvest_model_kv(arch: str = "smollm-135m", tokens: int = 512,
                     train_steps: int = 0, seed: int = 0):
    """Run the repo's own (smoke) model over synthetic text and return the
    per-layer KV tensors [(tokens, channels) bf16] — real KV, not surrogate.

    ``train_steps`` > 0 briefly trains first so the KV statistics move from
    random-init toward a trained model's (channel structure emerges fast).
    """
    import jax
    import jax.numpy as jnp
    import ml_dtypes

    from repro.configs.base import get_config
    from repro.data import DataConfig, ShardedLoader
    from repro.models.model import build_model
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)

    dc = DataConfig(vocab=cfg.vocab, seq_len=min(tokens, 256), global_batch=8, seed=seed)
    loader = ShardedLoader(dc)
    if train_steps:
        opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=train_steps)
        opt = adamw_init(params)

        @jax.jit
        def step(params, opt, batch):
            loss, g = jax.value_and_grad(model.loss)(params, batch)
            params, opt, _ = adamw_update(g, opt, params, opt_cfg)
            return params, opt, loss

        for s in range(train_steps):
            b = loader.batch_at(s)
            params, opt, loss = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})

    dc_long = DataConfig(vocab=cfg.vocab, seq_len=tokens, global_batch=1, seed=seed + 1)
    prompt = ShardedLoader(dc_long).batch_at(0)["tokens"]
    _, cache = jax.jit(model.prefill)(params, {"tokens": jnp.asarray(prompt)})
    k_np = np.asarray(cache["k"], np.float32)  # (L, 1, S, H, hd)
    out = []
    for li in range(k_np.shape[0]):
        out.append(k_np[li, 0].reshape(tokens, -1).astype(ml_dtypes.bfloat16))
    return out
