"""Paged vs sharded KV backends under the same Poisson serving load.

Drives the continuous-batching scheduler with identical mixed-length
traffic through ``backend="paged"`` (one memory tier) and
``backend="sharded"`` (per-shard compressed tier + lane engine, pages
routed by KV-head ownership via the runtime/sharding mesh rules) and puts
the trade side by side:

* throughput and occupancy are identical by construction (the device
  compute path is shared — the backends differ in the MEMORY tier), which
  the table makes visible instead of assuming;
* capacity/bandwidth savings drop slightly with head-sharding (each shard
  entropy-codes a narrower channel slice, so cross-channel correlation is
  lost at the shard boundary) — the honest cost of shard isolation;
* engine pressure halves per shard: per-shard utilization and the worst
  shard's modeled latency show the scale-out headroom Table IV's silicon
  buys when it is instantiated per shard.

    PYTHONPATH=src python -m benchmarks.run --only serving_sharded
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, pct


def _mixed_requests(n, seed, vocab):
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    return [
        Request(rid=i,
                prompt=rng.integers(0, vocab, int(rng.integers(8, 120)))
                .astype(np.int32),
                max_new_tokens=int(rng.choice([4, 8, 16, 24])))
        for i in range(n)
    ]


def _run(model, params, cfg, reqs, arrivals, max_steps=None):
    from repro.serving import ContinuousScheduler

    sched = ContinuousScheduler(model, params, cfg)
    nxt = 0
    while nxt < len(reqs) or sched.has_work():
        if max_steps is not None and sched.step_count >= max_steps:
            break
        while nxt < len(reqs) and arrivals[nxt] <= sched.step_count:
            sched.submit(reqs[nxt])
            nxt += 1
        sched.step()
    return sched.report()


def run(n_requests: int = 24, rate: float = 0.6, shards: int = 2,
        seed: int = 0, max_steps: int | None = None):
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.core.quantization import PrecisionLadder
    from repro.memctl import MemCtlConfig
    from repro.models.model import build_model
    from repro.serving import EngineConfig

    cfg_m = get_config("smollm-135m", smoke=True)
    model = build_model(cfg_m)
    params = model.init(jax.random.PRNGKey(0))
    base = EngineConfig(
        max_batch=4, max_ctx=256,
        ladder=PrecisionLadder([(4, 16), (4, 12), (-1, 8)]),
        max_stored_bytes=128 * 1024,
        engine=MemCtlConfig(lanes=4, step_cycles=1024),
    )
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(rng.exponential(1.0 / rate, n_requests)))

    # warm the shared jit cache so neither mode's tok/s carries the compile
    # bill — this benchmark compares MEMORY tiers, not compile schedules
    # (benchmarks/serving_throughput owns the cold-compile comparison)
    _run(model, params, dataclasses.replace(base, backend="paged"),
         _mixed_requests(2, seed + 1, cfg_m.vocab), np.zeros(2))

    out = {}
    rows = []
    for name, cfg in (
        ("paged", dataclasses.replace(base, backend="paged")),
        (f"sharded x{shards}",
         dataclasses.replace(base, backend="sharded", shards=shards)),
    ):
        rep = _run(model, params, cfg,
                   _mixed_requests(n_requests, seed, cfg_m.vocab),
                   arrivals, max_steps=max_steps)
        rows.append([
            name,
            f"{rep.get('decode_tok_per_s', 0):.1f}",
            pct(rep.get("mean_batch_occupancy", 0)),
            pct(rep.get("kv_capacity_saving", 0)),
            pct(rep.get("kv_bandwidth_saving", 0)),
            f"{rep['kv_evictions']:.0f}",
            pct(rep["engine_utilization"]),
            f"{rep['engine_modeled_latency_ns'] / 1e3:.1f}us",
        ])
        out[name] = {
            "decode_tok_per_s": rep.get("decode_tok_per_s", 0),
            "kv_capacity_saving": rep.get("kv_capacity_saving", 0),
            "kv_bandwidth_saving": rep.get("kv_bandwidth_saving", 0),
            "engine_utilization": rep["engine_utilization"],
            "engine_modeled_latency_ns": rep["engine_modeled_latency_ns"],
            "shards": rep.get("shards"),
        }
    print(fmt_table(rows, ["backend", "tok/s", "occupancy", "KV capacity",
                           "KV bandwidth", "evictions", "engine util",
                           "modeled lat"]))
    sh = out[f"sharded x{shards}"]["shards"] or []
    if sh:
        per = ", ".join(
            f"shard{d['shard']}: {pct(d['engine_utilization'])} util / "
            f"{d['kv_stored_bytes'] / 1024:.0f} KiB stored" for d in sh
        )
        print(f"\n[serving_sharded] per-shard balance — {per}")
    print("[serving_sharded] same device compute, different memory tier: "
          "savings trade a few points for per-shard stores + lane engines "
          "(worst-shard latency is the quoted modeled latency)")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=0.6)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=None)
    a = ap.parse_args()
    run(n_requests=a.requests, rate=a.rate, shards=a.shards, seed=a.seed,
        max_steps=a.steps)
