"""§Roofline: the three-term analysis per (arch × shape × mesh), read from
the dry-run's JSONL output (results/dryrun_all.jsonl by default).

Run the sweep first:
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --json results/dryrun_all.jsonl
"""

from __future__ import annotations

import json
import os

from benchmarks.common import fmt_table

DEFAULT_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "dryrun_all.jsonl",
)


def load(path: str = DEFAULT_PATH) -> list:
    if not os.path.exists(path):
        return []
    rows = []
    seen = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            seen[(r["arch"], r["shape"], r["mesh"])] = r  # last wins
    rows = list(seen.values())
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    return rows


def run(path: str = DEFAULT_PATH) -> dict:
    rows = load(path)
    if not rows:
        print(f"[roofline] no dry-run results at {path}; run the sweep first")
        return {}
    table = []
    for r in rows:
        table.append([
            r["arch"], r["shape"], r["mesh"],
            f"{r['t_compute_s'] * 1e3:9.2f}",
            f"{r['t_memory_s'] * 1e3:9.2f}",
            f"{r['t_collective_s'] * 1e3:9.2f}",
            r["bottleneck"],
            f"{r['useful_flops_frac']:.2f}",
            f"{r['mfu_bound']:.3f}",
        ])
    print("\n== §Roofline: three-term analysis (ms per step, per device) ==")
    print(fmt_table(table, ["arch", "shape", "mesh", "t_comp", "t_mem",
                            "t_coll", "bound", "useful", "mfu_bound"]))
    by_bound = {}
    for r in rows:
        by_bound[r["bottleneck"]] = by_bound.get(r["bottleneck"], 0) + 1
    print(f"bottleneck distribution: {by_bound}")
    return {f"{r['arch']}/{r['shape']}/{r['mesh']}": r for r in rows}


if __name__ == "__main__":
    run()
