"""Bounded vs. unbounded (de)compression engine under the Poisson trace.

Drives the continuous-batching scheduler twice over the same Poisson
arrival workload: once with the paper's finite engine (lane pool + per-step
service window, memctl runtime) and once with the unbounded engine the old
accounting assumed (``MemCtlConfig(step_cycles=None)``).  The deltas are the
whole point of ISSUE 2: the bounded engine shows real lane utilization,
queue depth, deferred work, and engine-limited latency, while savings stay
comparable — i.e. the modeled silicon can (or cannot) actually sustain the
accounting the serving path quotes.

    PYTHONPATH=src python -m benchmarks.run --only engine_util
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, pct
# same Poisson workload + drive loop as the serving benchmark, on purpose:
# the two must diverge only in engine config
from benchmarks.serving_throughput import _mixed_requests, _run_continuous as _run


def run(n_requests: int = 16, rate: float = 0.7, seed: int = 0,
        lanes: int = 2, step_cycles: int = 256, max_steps: int | None = None):
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.core.quantization import PrecisionLadder
    from repro.memctl import MemCtlConfig
    from repro.models.model import build_model
    from repro.serving import EngineConfig

    cfg_m = get_config("smollm-135m", smoke=True)
    model = build_model(cfg_m)
    params = model.init(jax.random.PRNGKey(0))
    base = EngineConfig(
        max_batch=4, max_ctx=256,
        ladder=PrecisionLadder([(4, 16), (4, 12), (-1, 8)]),
        max_stored_bytes=96 * 1024,
    )
    modes = {
        "bounded": dataclasses.replace(
            base, engine=MemCtlConfig(lanes=lanes, step_cycles=step_cycles)),
        "unbounded": dataclasses.replace(
            base, engine=MemCtlConfig(step_cycles=None)),
    }

    rng = np.random.default_rng(seed)
    arrivals = np.floor(
        np.cumsum(rng.exponential(1.0 / rate, n_requests))
    ).astype(np.int64)

    # warm the shared jit cache so both modes run on equal footing
    _run(model, params, base, _mixed_requests(2, seed + 1, cfg_m.vocab),
         np.zeros(2, np.int64))

    rows, out = [], {}
    for name, cfg in modes.items():
        rep = _run(model, params, cfg,
                   _mixed_requests(n_requests, seed, cfg_m.vocab),
                   arrivals, max_steps=max_steps)
        er = rep["engine"]
        rows.append([
            name,
            pct(rep.get("engine_utilization", 0)),
            f"{er['queue_depth']['p50']:.0f}/{er['queue_depth']['p99']:.0f}",
            f"{rep['engine_deferred_jobs']:.0f}",
            f"{rep['engine_modeled_latency_ns'] / 1e3:.1f}",
            f"{rep['kv_reactivations']:.0f}",
            pct(rep.get("kv_bandwidth_saving", 0)),
        ])
        out[name] = {
            "utilization": rep.get("engine_utilization", 0),
            "queue_depth": er["queue_depth"],
            "deferred_jobs": rep["engine_deferred_jobs"],
            "modeled_latency_ns": rep["engine_modeled_latency_ns"],
            "serviced_bytes": er["serviced_bytes"],
            "step_budget_bytes": er["step_budget_bytes"],
            "kv_reactivations": rep["kv_reactivations"],
            "kv_bandwidth_saving": rep.get("kv_bandwidth_saving", 0),
            "silicon": er["silicon"],
        }
    print(fmt_table(rows, ["engine", "lane util", "queue p50/p99",
                           "deferred", "latency us", "reactivations",
                           "KV bandwidth"]))
    b = out["bounded"]
    print(f"\n[engine_util] {lanes} lane(s) x {step_cycles} cycles/step "
          f"({b['step_budget_bytes']} B/window): "
          f"{pct(b['utilization'])} busy, p99 queue "
          f"{b['queue_depth']['p99']:.0f} jobs — the unbounded accounting "
          f"hides all of this")
    return out


if __name__ == "__main__":
    run()
