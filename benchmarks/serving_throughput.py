"""Serving throughput under load: admission modes and batching modes.

Drives the continuous-batching scheduler with a Poisson arrival trace of
mixed-length requests and reports decode tokens/s, batch occupancy, prefill
compile count / wall time, and the KV capacity/bandwidth savings the
compressed store + dynamic-quantization ladder deliver at steady state
(normalised per 1k requests).  Three modes, each on a FRESH model object so
prefill numbers include its own compiles (that is the point of bucketing):

* ``bucketed``   — chunked prefill over power-of-two length buckets
  (<= log2(max_ctx) compiles, pad-free accounting; ISSUE 3 tentpole).
* ``left-pad``   — the legacy pad-to-``prefill_align`` admission: one
  compile per distinct padded prompt length, pad KV stored and charged.
* ``one-shot waves`` — left-pad admission AND fixed admission waves (the
  seed engine's behaviour): every wave decodes to its longest request.

Savings are quoted over pad-free logical bytes only — the left-pad rows
inflate ``prefill_tokens`` and the store traffic, which is visible in the
table instead of flattering it.

    PYTHONPATH=src python -m benchmarks.run --only serving
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, pct


def _mixed_requests(n, seed, vocab, max_new_choices=(4, 8, 16, 24)):
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(8, 120))
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new_tokens=int(rng.choice(max_new_choices)),
        ))
    return reqs


def _run_continuous(model, params, cfg, reqs, arrivals, max_steps=None):
    from repro.serving import ContinuousScheduler

    sched = ContinuousScheduler(model, params, cfg)
    next_req = 0
    while next_req < len(reqs) or sched.has_work():
        if max_steps is not None and sched.step_count >= max_steps:
            break
        while next_req < len(reqs) and arrivals[next_req] <= sched.step_count:
            sched.submit(reqs[next_req])
            next_req += 1
        sched.step()
    return sched.report()


def _run_waves(model, params, cfg, reqs, max_steps=None):
    """Seed-style one-shot batching: admit in fixed waves of max_batch."""
    from repro.serving import ServingEngine

    eng = ServingEngine(model, params, cfg)
    budget = max_steps
    for off in range(0, len(reqs), cfg.max_batch):
        if budget is not None and budget <= 0:
            break
        wave = reqs[off : off + cfg.max_batch]
        # one-shot semantics: nothing joins until the whole wave drains
        for r in wave:
            eng.scheduler.submit(r)
        before = eng.scheduler.step_count
        eng.scheduler.run_until_drained(
            max_steps=budget if budget is not None else 100_000
        )
        if budget is not None:
            budget -= eng.scheduler.step_count - before
    return eng.report()


def run(n_requests: int = 24, rate: float = 0.6, seed: int = 0,
        max_steps: int | None = None):
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.core.quantization import PrecisionLadder
    from repro.models.model import build_model
    from repro.serving import EngineConfig

    cfg_m = get_config("smollm-135m", smoke=True)
    params = build_model(cfg_m).init(jax.random.PRNGKey(0))
    ladder = PrecisionLadder([(4, 16), (4, 12), (-1, 8)])
    base_cfg = EngineConfig(max_batch=4, max_ctx=256, ladder=ladder,
                            max_stored_bytes=128 * 1024)

    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)

    def fresh(mode):
        # a fresh Model object = a cold jit cache, so each mode pays (and
        # reports) exactly its own prefill compiles
        return build_model(cfg_m), dataclasses.replace(base_cfg,
                                                       prefill_mode=mode)

    model, cfg = fresh("bucketed")
    bucketed = _run_continuous(model, params, cfg,
                               _mixed_requests(n_requests, seed, cfg_m.vocab),
                               arrivals, max_steps=max_steps)
    model, cfg = fresh("padded")
    leftpad = _run_continuous(model, params, cfg,
                              _mixed_requests(n_requests, seed, cfg_m.vocab),
                              arrivals, max_steps=max_steps)
    model, cfg = fresh("padded")
    wave = _run_waves(model, params, cfg,
                      _mixed_requests(n_requests, seed, cfg_m.vocab),
                      max_steps=max_steps)

    rows = []
    out = {}
    for name, rep in (("bucketed", bucketed), ("left-pad", leftpad),
                      ("one-shot waves", wave)):
        rows.append([
            name,
            f"{rep['prefill_compiles']:.0f}",
            f"{rep['prefill_s']:.2f}s",
            f"{rep.get('decode_tok_per_s', 0):.1f}",
            f"{rep['decode_steps']:.0f}",
            pct(rep.get("mean_batch_occupancy", 0)),
            pct(rep.get("kv_capacity_saving", 0)),
            pct(rep.get("kv_bandwidth_saving", 0)),
            f"{rep['kv_evictions']:.0f}",
        ])
        out[name] = {
            "prefill_compiles": rep["prefill_compiles"],
            "prefill_s": rep["prefill_s"],
            "prefill_tokens": rep["prefill_tokens"],
            "decode_tok_per_s": rep.get("decode_tok_per_s", 0),
            "decode_steps": rep["decode_steps"],
            "occupancy": rep.get("mean_batch_occupancy", 0),
            "kv_capacity_saving": rep.get("kv_capacity_saving", 0),
            "kv_bandwidth_saving": rep.get("kv_bandwidth_saving", 0),
            "per_1k_requests": rep.get("per_1k_requests", {}),
        }
    print(fmt_table(rows, ["mode", "compiles", "prefill", "tok/s", "steps",
                           "occupancy", "KV capacity", "KV bandwidth",
                           "evictions"]))
    steps_c, steps_w = bucketed["decode_steps"], wave["decode_steps"]
    print(f"\n[serving] bucketed admission: "
          f"{bucketed['prefill_compiles']:.0f} prefill compiles vs "
          f"{leftpad['prefill_compiles']:.0f} left-pad "
          f"({bucketed['prefill_s']:.2f}s vs {leftpad['prefill_s']:.2f}s "
          f"prefill); pad-free prefill tokens "
          f"{bucketed['prefill_tokens']:.0f} vs "
          f"{leftpad['prefill_tokens']:.0f}")
    print(f"[serving] continuous batching: {steps_c:.0f} decode steps vs "
          f"{steps_w:.0f} one-shot ({pct(1 - steps_c / max(1, steps_w))} fewer); "
          f"retire-at-own-step reclaims the padded-decode waste")
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=0.6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--steps", type=int, default=None,
                    help="cap scheduler steps per mode (CI-sized runs)")
    a = ap.parse_args()
    run(n_requests=a.requests, rate=a.rate, seed=a.seed, max_steps=a.steps)
