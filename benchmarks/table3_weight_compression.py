"""Paper Table III: weight compression ratios by precision (BF16/FP8/INT4)
with bit-plane + ZSTD, and total savings when stacked on lossy quantization.
"""

from __future__ import annotations

from benchmarks.common import fmt_table, pct
from repro.core.bitplane import BF16, FP8_E4M3, INT4
from repro.core.compressed_store import StoreConfig, compress_weights
from repro.core.surrogates import (
    gaussian_weights,
    quantized_weights_fp8,
    quantized_weights_int4,
)

MODELS = {
    "llama8b-like": (4096, 4096),
    "llama70b-like": (8192, 8192),
    "mixtral-like": (4096, 14336),
}

#: lossy savings vs BF16 (FP8 halves, INT4 quarters) — paper's framing
LOSSY = {"bf16": 0.0, "fp8": 0.5, "int4": 0.75}


def run() -> dict:
    cfg = StoreConfig(codec="zstd")
    rows, out = [], {}
    for name, shape in MODELS.items():
        seed = hash(name) % 97
        variants = {
            "bf16": (gaussian_weights(shape, seed=seed), BF16),
            "fp8": (quantized_weights_fp8(shape, seed=seed), FP8_E4M3),
            "int4": (quantized_weights_int4(shape, seed=seed), INT4),
        }
        for prec, (w, spec) in variants.items():
            ct = compress_weights(w, spec, cfg)
            # the ONE savings definition (shared with the serving path's
            # report()["weights"]): quoted over exact block bytes, never
            # padded bytes — identical to the old 1 - 1/ratio here because
            # offline tensors are unpadded, but now provably the same
            # number the weight streamer reports for the same surrogates
            lossless = ct.exact_savings
            total = 1 - (1 - LOSSY[prec]) * (1 - lossless)
            rows.append([
                name, prec, f"{ct.exact_ratio:.2f}", pct(lossless),
                pct(total),
            ])
            out[f"{name}_{prec}"] = {
                "ratio": ct.exact_ratio, "lossless": lossless,
                "total": total,
            }
    print("\n== Table III: weight lossless ratios + stacked savings ==")
    print(fmt_table(rows, ["model", "precision", "ratio", "lossless", "total"]))
    print("paper: bf16 1.32-1.34 (24-26%), fp8 1.09-1.11 (8-10%, total ~54%), "
          "int4 1.01-1.02 (1-2%, total ~75%)")
    return out


if __name__ == "__main__":
    run()
