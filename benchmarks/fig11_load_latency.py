"""Paper Fig. 11: average model load latency, Proposed (P) vs Traditional
(T), across models × base precisions (same scenarios as Fig. 10)."""

from __future__ import annotations

from benchmarks.common import fmt_table, pct
from benchmarks.fig10_dram_energy import FETCH_FRAC, SCENARIOS, _trace
from repro.memsim.trace import replay_controller_trace


def run() -> dict:
    rows, out = [], {}
    for (model, base), (gb, ratio, _) in SCENARIOS.items():
        frac = FETCH_FRAC[base]
        t = replay_controller_trace(_trace(gb, 1.0, model))
        p = replay_controller_trace(_trace(gb, frac / ratio, model))
        rows.append([
            model, base, f"{t.elapsed_ms:8.2f}", f"{p.elapsed_ms:8.2f}",
            pct(1 - p.elapsed_ns / t.elapsed_ns),
        ])
        out[f"{model}_{base}"] = {
            "latency_T_ms": t.elapsed_ms, "latency_P_ms": p.elapsed_ms,
            "reduction": 1 - p.elapsed_ns / t.elapsed_ns,
        }
    print("\n== Fig. 11: model load latency, Proposed vs Traditional ==")
    print(fmt_table(rows, ["model", "base", "T (ms)", "P (ms)", "reduction"]))
    print("paper: mixtral bf16 705.9->495.1 ms (-30.0%); llama70b bf16 "
          "910.6->674.7 ms (-25.9%); fp8 ~17%, int4 ~14.5%")
    return out


if __name__ == "__main__":
    run()
